"""Scatter-gather (vectored) encode pipeline tests.

Differential guarantees: ``b"".join(encode_vectored(x))`` must equal the
oracle encoding byte-exactly for every message type and every random value
the contiguous fast path accepts; ``ScatterPayload`` must behave like the
joined bytes under len/indexing/slicing; borrowed segments must alias
their source buffers (the zero-copy property itself); and the wire /
checkpoint layers must accept vectored payloads end to end.
"""
import io
import tracemalloc
import uuid
import zlib

import numpy as np
import pytest

from repro.core import cbor, cddl, fastpath
from repro.core.cbor import Tag
from repro.core.fastpath import ScatterPayload
from repro.core.messages import (
    FLChunkAck,
    FLChunkNack,
    FLGlobalModelUpdate,
    FLLocalDataSetUpdate,
    FLLocalModelUpdate,
    FLModelChunk,
    ModelMetadata,
    ParamsEncoding,
    missing_to_ranges,
    ranges_to_missing,
)
from repro.fl.chunking import AssemblerReceiver, chunk_stream, run_selective_repeat
from repro.transport.coap import Code
from repro.transport.network import LossyLink, as_wire_payload

from test_fastpath import _random_value

MID = uuid.UUID(bytes=bytes(range(16)))


# -- differential: joined segments == oracle bytes -----------------------------


def test_vectored_differential_fuzz():
    rng = np.random.default_rng(4321)
    for _ in range(300):
        value = _random_value(rng)
        oracle = cbor.encode(value)
        assert b"".join(fastpath.encode_vectored(value)) == oracle, value


def test_vectored_differential_all_message_types_all_encodings():
    rng = np.random.default_rng(7)
    params = rng.standard_normal(257).astype(np.float32)
    g = FLGlobalModelUpdate(MID, 5, params, True)
    l = FLLocalModelUpdate(MID, 5, params, ModelMetadata(0.5, 0.25))
    d = FLLocalDataSetUpdate(640, ModelMetadata(0.5, 0.25))
    c = FLModelChunk(MID, 5, 1, 3, 0xDEADBEEF, params)
    encodings = [ParamsEncoding.TA_F16, ParamsEncoding.TA_F32,
                 ParamsEncoding.TA_F64, ParamsEncoding.TA_BF16,
                 ParamsEncoding.Q8, ParamsEncoding.DYNAMIC]
    for enc in encodings:
        for m in (g, l, c):
            assert b"".join(m.to_cbor_segments(enc)) == \
                m.to_cbor(enc, fast=False), (type(m).__name__, enc)
    assert b"".join(d.to_cbor_segments()) == d.to_cbor(fast=False)
    assert b"".join(d.to_cbor_segments(worst=True)) == \
        d.to_cbor(worst=True, fast=False)
    assert b"".join(g.to_cbor_segments(ParamsEncoding.ARRAY_F64, worst=True)) \
        == g.to_cbor(ParamsEncoding.ARRAY_F64, worst=True, fast=False)
    nack = FLChunkNack(MID, 3, 64, (1, 2, 3, 9))
    ack = FLChunkAck(MID, 3, 64)
    assert b"".join(nack.to_cbor_segments()) == nack.to_cbor(fast=False)
    assert b"".join(ack.to_cbor_segments()) == ack.to_cbor(fast=False)


def test_vectored_kernel_payload_splice():
    """Pallas kernel output -> message with zero intermediate bytes."""
    import jax.numpy as jnp
    from repro.kernels.quantize_f16.ops import (
        params_to_f16_payload,
        params_to_f16_payload_into,
        params_to_f16_view,
    )

    flat = np.random.default_rng(0).standard_normal(2048).astype(np.float32)
    jflat = jnp.asarray(flat)
    msg = FLGlobalModelUpdate(MID, 1, flat, True)
    view = params_to_f16_view(jflat)
    owned = params_to_f16_payload(jflat)
    assert bytes(view) == owned
    assert b"".join(msg.to_cbor_segments(ParamsEncoding.TA_F16,
                                         params_payload=view)) == \
        msg.to_cbor(ParamsEncoding.TA_F16, params_payload=owned, fast=False)
    # *_into: same payload, caller-owned memory
    buf = bytearray(len(owned) + 8)
    n = params_to_f16_payload_into(jflat, buf)
    assert n == len(owned) and bytes(buf[:n]) == owned
    with pytest.raises(ValueError):
        params_to_f16_payload_into(jflat, bytearray(3))


def test_vectored_q8_kernel_wire_item():
    import jax.numpy as jnp
    from repro.core.params_codec import decode_q8
    from repro.kernels.q8_block.ops import (
        BLOCK,
        compress_update,
        compress_update_into,
        q8_wire_item,
    )

    n = 1000
    flat = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    jflat = jnp.asarray(flat)
    item = q8_wire_item(jflat)
    wire = b"".join(fastpath.encode_vectored(item))
    out = decode_q8(fastpath.decode(wire))
    q, scales, err = compress_update(jflat)
    np.testing.assert_allclose(out, np.asarray(flat) - np.asarray(err),
                               rtol=1e-6, atol=1e-6)
    # compress_update_into writes the padded wire layout into caller buffers
    nblocks = -(-n // BLOCK)
    qb, sb = bytearray(nblocks * BLOCK), bytearray(nblocks * 4)
    qn, sn = compress_update_into(jflat, qb, sb)
    assert (qn, sn) == (nblocks * BLOCK, nblocks * 4)
    np.testing.assert_array_equal(np.frombuffer(qb, np.int8)[:n],
                                  np.asarray(q))
    np.testing.assert_array_equal(np.frombuffer(sb, "<f4"),
                                  np.asarray(scales))


# -- the zero-copy property itself ---------------------------------------------


def test_payload_segments_borrow_source_buffers():
    arr = np.arange(100_000, dtype=np.float32)
    segs = fastpath.encode_vectored(arr)
    assert len(segs) == 2                       # heads + borrowed payload
    assert all(isinstance(s, memoryview) and s.readonly for s in segs)
    assert np.shares_memory(np.frombuffer(segs[1], np.float32), arr)
    # message-level: the params payload aliases the live vector
    msg = FLGlobalModelUpdate(MID, 1, arr, True)
    segs = msg.to_cbor_segments(ParamsEncoding.TA_F32)
    payload = max(segs, key=lambda s: s.nbytes)
    assert np.shares_memory(np.frombuffer(payload, np.float32), arr)


def test_small_payloads_coalesce_into_scratch():
    # sub-threshold payloads ride in the owned header segment: one segment
    segs = fastpath.encode_vectored([1, b"tiny", "abc", 2.5])
    assert len(segs) == 1


def test_vectored_encode_peak_alloc_is_headers_only():
    flat = np.zeros(1_000_000, np.float32)
    msg = FLGlobalModelUpdate(MID, 1, flat, True)
    msg.to_cbor_segments(ParamsEncoding.TA_F32)   # warm caches
    tracemalloc.start()
    msg.to_cbor_segments(ParamsEncoding.TA_F32)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak <= 64 * 1024, f"vectored encode allocated {peak} bytes"


# -- ScatterPayload semantics --------------------------------------------------


def test_scatter_payload_matches_joined_bytes():
    rng = np.random.default_rng(17)
    value = [rng.bytes(700), 1, "x" * 600, np.arange(333, dtype=np.int16),
             {"k": rng.bytes(5)}]
    ref = fastpath.encode(value)
    sp = ScatterPayload(fastpath.encode_vectored(value))
    assert len(sp) == len(ref)
    assert sp.tobytes() == ref
    assert bytes(fastpath.vectored_bytes(fastpath.encode_vectored(value))) \
        == ref
    assert fastpath.vectored_nbytes(fastpath.encode_vectored(value)) == \
        len(ref)
    for start, stop in [(0, 0), (0, 1), (0, 64), (3, 77), (699, 705),
                        (len(ref) - 5, len(ref) + 10), (0, len(ref))]:
        assert sp[start:stop] == ref[start:stop], (start, stop)
    for i in (0, 1, 699, 700, len(ref) - 1, -1):
        assert sp[i] == ref[i], i
    with pytest.raises(IndexError):
        sp[len(ref)]
    with pytest.raises(ValueError):
        sp[0:10:2]


def test_scatter_payload_blockwise_framing_without_join():
    """The CoAP framer slices a ScatterPayload in ≤64 B blocks; frame
    accounting must equal the contiguous-bytes framing exactly."""
    from repro.transport.coap import blockwise_messages

    value = [np.arange(5000, dtype=np.float32), b"z" * 1000]
    ref = fastpath.encode(value)
    sp = ScatterPayload(fastpath.encode_vectored(value))
    msgs_ref = blockwise_messages(ref, uri="fl/model")
    msgs_sp = blockwise_messages(sp, uri="fl/model")
    assert len(msgs_ref) == len(msgs_sp)
    for a, b in zip(msgs_ref, msgs_sp):
        assert a.encode() == b.encode()


def test_link_accepts_vectored_payloads():
    value = [np.arange(2000, dtype=np.float32)]
    ref = fastpath.encode(value)
    segs = fastpath.encode_vectored(value)
    link_a = LossyLink(drop_prob=0.2, seed=42)
    link_b = LossyLink(drop_prob=0.2, seed=42)
    sa = link_a.send_payload(ref, uri="fl/model")
    sb = link_b.send_payload(segs, uri="fl/model")   # raw segment list
    assert vars(sa) == vars(sb)
    assert as_wire_payload(segs).tobytes() == ref
    stream = LossyLink(drop_prob=0.0).send_stream(
        [segs, ScatterPayload(segs), ref], uri="fl/model")
    assert stream.payload_bytes == 3 * len(ref)


def test_selective_repeat_over_vectored_wires():
    """End-to-end: chunk stream -> vectored wires -> link -> reassembly,
    byte-identical under loss, with repair accounting intact."""
    params = np.random.default_rng(5).standard_normal(20_000).astype(
        np.float32)
    chunks = list(chunk_stream(MID, 1, params, 1024))

    def drop(uri, window, index, receiver):
        return window == 0 and index in (3, 7)

    link = LossyLink(drop_prob=0.0, seed=1, chunk_drop=drop)
    receivers = [AssemblerReceiver()]
    report = run_selective_repeat(
        link, chunks, receivers, uri="fl/model/chunk",
        feedback_uri="fl/model/chunk/fb", multicast=True)
    assert report.completed == [0]
    assert receivers[0].assembled.tobytes() == params.tobytes()
    assert report.retransmitted_chunks == 2
    assert report.retransmitted_payload_bytes == \
        len(chunks[3].to_cbor()) + len(chunks[7].to_cbor())


def test_sequence_writer_segments_file_and_buffer_sinks(tmp_path):
    value = {"h": 1}
    arr = np.arange(4096, dtype=np.float64)
    segs = fastpath.encode_vectored(value) + fastpath.encode_vectored(arr)
    ref = b"".join(segs)
    # real file: os.writev gather path
    p = tmp_path / "seq.cbor"
    with open(p, "wb") as f:
        w = fastpath.CBORSequenceWriter(f)
        assert w.write_segments(segs) == len(ref)
        assert w.bytes_written == len(ref)
    assert p.read_bytes() == ref
    # BytesIO: sequential fallback
    sink = io.BytesIO()
    fastpath.CBORSequenceWriter(sink).write_segments(segs)
    assert sink.getvalue() == ref


# -- compact NACK ranges -------------------------------------------------------


def test_missing_ranges_roundtrip_and_compression():
    cases = [
        ((0,), [0, 1]),
        ((3, 4, 5), [3, 3]),
        ((1, 3, 5), [1, 1, 3, 1, 5, 1]),
        (tuple(range(100, 600)), [100, 500]),
        ((7, 7, 7, 8), [7, 2]),               # duplicates collapse
    ]
    for missing, ranges in cases:
        assert missing_to_ranges(missing) == ranges
        assert ranges_to_missing(ranges) == \
            tuple(sorted(set(int(i) for i in missing)))


def test_nack_wire_is_range_pairs_and_shrinks_bursty_losses():
    burst = FLChunkNack(MID, 2, 4096, tuple(range(1000, 1512)))
    wire = burst.to_cbor()
    # 512 missing indices travel as one (start, count) pair
    item = fastpath.decode(wire)
    assert item[3] == [1000, 512]
    assert len(wire) < 40
    cddl.validate(item, cddl.SCHEMAS["FL_Chunk_Nack"])
    assert FLChunkNack.from_cbor(wire) == burst
    # scattered losses still roundtrip exactly
    sparse = FLChunkNack(MID, 2, 4096, (5, 100, 101, 4000))
    assert FLChunkNack.from_cbor(sparse.to_cbor()) == sparse
    cddl.validate(fastpath.decode(sparse.to_cbor()),
                  cddl.SCHEMAS["FL_Chunk_Nack"])


def test_nack_rejects_malformed_ranges():
    good = FLChunkNack(MID, 1, 16, (2, 3)).to_cbor()
    item = fastpath.decode(good)
    # odd-length pair list
    bad = fastpath.encode([item[0], item[1], item[2], [2, 1, 5]])
    with pytest.raises(ValueError):
        FLChunkNack.from_cbor(bad)
    # zero-count range
    bad = fastpath.encode([item[0], item[1], item[2], [2, 0]])
    with pytest.raises(ValueError):
        FLChunkNack.from_cbor(bad)
    # empty pair list
    bad = fastpath.encode([item[0], item[1], item[2], []])
    with pytest.raises(ValueError):
        FLChunkNack.from_cbor(bad)
    with pytest.raises(Exception):
        cddl.validate(fastpath.decode(bad), cddl.SCHEMAS["FL_Chunk_Nack"])


def test_nack_range_expansion_is_bounded_by_num_chunks():
    """A hostile ~30-byte NACK must not materialize a multi-GB index tuple:
    ranges beyond num-chunks are rejected before expansion, and a claimed
    num-chunks is itself untrusted — the decode caps it unless the caller
    vouches for the generation size."""
    from repro.core.messages import MAX_NACK_CHUNKS

    item = fastpath.decode(FLChunkNack(MID, 1, 16, (2,)).to_cbor())
    for evil in ([0, 10_000_000], [15, 2], [16, 1]):
        wire = fastpath.encode([item[0], item[1], item[2], evil])
        with pytest.raises(ValueError, match="exceeds num-chunks"):
            FLChunkNack.from_cbor(wire, expect_num_chunks=16)
    # num-chunks comes from the same untrusted wire: a self-consistent
    # huge claim is rejected by the cap (no expansion)...
    huge = fastpath.encode([item[0], item[1], 2**40, [0, 2**40]])
    with pytest.raises(ValueError, match="MAX_NACK_CHUNKS"):
        FLChunkNack.from_cbor(huge)
    big = fastpath.encode([item[0], item[1], MAX_NACK_CHUNKS + 1,
                           [0, MAX_NACK_CHUNKS + 1]])
    with pytest.raises(ValueError, match="MAX_NACK_CHUNKS"):
        FLChunkNack.from_cbor(big)
    # ...and by the generation-size mismatch when the caller knows it
    with pytest.raises(ValueError, match="!= this generation"):
        FLChunkNack.from_cbor(huge, expect_num_chunks=16)
    # overlapping / unsorted pairs would defeat the bound (repeat one
    # in-range pair to inflate the expansion) — rejected before expanding
    for evil in ([0, 16, 0, 16], [4, 4, 2, 4], [8, 2, 0, 2]):
        wire = fastpath.encode([item[0], item[1], item[2], evil])
        with pytest.raises(ValueError, match="non-overlapping"):
            FLChunkNack.from_cbor(wire, expect_num_chunks=16)
    # the full in-range set is still fine
    full = fastpath.encode([item[0], item[1], item[2], [0, 16]])
    assert FLChunkNack.from_cbor(full).missing == tuple(range(16))
    assert FLChunkNack.from_cbor(full, expect_num_chunks=16).num_chunks == 16


def test_contiguous_and_vectored_agree_on_multidim_payload_views():
    """A 2-D byte view as params_payload must encode identically through
    the contiguous, vectored and oracle paths (byte length, not rows)."""
    view = memoryview(np.arange(2048, dtype=np.uint8).reshape(2, 1024))
    msg = FLGlobalModelUpdate(MID, 1, np.zeros(1024, np.float16), True)
    contiguous = msg.to_cbor(ParamsEncoding.TA_F16, params_payload=view)
    assert contiguous == b"".join(
        msg.to_cbor_segments(ParamsEncoding.TA_F16, params_payload=view))
    assert contiguous == msg.to_cbor(ParamsEncoding.TA_F16,
                                     params_payload=view, fast=False)


def test_assembler_buffers_are_owned_not_sender_aliases():
    """Receivers must own what they buffer: mutating the sender's live
    vector mid-transfer cannot corrupt buffered (or assembled) chunks."""
    params = np.arange(4096, dtype="<f4")
    chunks = list(chunk_stream(MID, 1, params, 1024))
    from repro.fl.chunking import ChunkAssembler
    asm = ChunkAssembler()
    asm.add(chunks[0])
    params[:] = -1.0   # sender mutates after partial delivery
    assert not np.may_share_memory(asm._buf, params)
    np.testing.assert_array_equal(asm._buf[:1024],
                                  np.arange(1024, dtype="<f4"))
    # the final (short) chunk parked before geometry is known is owned too
    params2 = np.arange(2500, dtype="<f4")
    tail = list(chunk_stream(MID, 2, params2, 1024))[-1]
    asm2 = ChunkAssembler()
    asm2.add(tail)
    params2[:] = -1.0
    assert not np.may_share_memory(asm2._pending_final, params2)
    np.testing.assert_array_equal(asm2._pending_final,
                                  np.arange(2048, 2500, dtype="<f4"))


def test_write_segments_beyond_iov_max(tmp_path):
    """More segments than the kernel's IOV_MAX must still write whole."""
    piece = bytes(range(256)) * 3   # 768 B, above BORROW_MIN -> borrowed
    value = [piece] * 3000          # ~3001 segments
    ref = fastpath.encode(value)
    p = tmp_path / "many.cbor"
    with open(p, "wb") as f:
        w = fastpath.CBORSequenceWriter(f)
        segs = fastpath.encode_vectored(value)
        assert len(segs) > 1024
        assert w.write_segments(segs) == len(ref)
    assert p.read_bytes() == ref


# -- hypothesis property (optional dev dep) ------------------------------------


try:
    import hypothesis
except ImportError:
    hypothesis = None

if hypothesis is not None:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    _scalars = st.one_of(
        st.integers(min_value=-2**63, max_value=2**64 - 1),
        st.floats(allow_nan=False),
        st.booleans(), st.none(), st.binary(max_size=2048),
        st.text(max_size=64),
    )
    _values = st.recursive(
        _scalars,
        lambda children: st.one_of(
            st.lists(children, max_size=6),
            st.dictionaries(st.integers(0, 1000), children, max_size=6),
            st.builds(Tag, st.integers(0, 2**32), children),
        ),
        max_leaves=25,
    )

    @settings(deadline=None, max_examples=150)
    @given(_values)
    def test_property_vectored_matches_oracle_and_roundtrips(value):
        oracle = cbor.encode(value)
        segs = fastpath.encode_vectored(value)
        assert b"".join(segs) == oracle
        sp = ScatterPayload(segs)
        assert len(sp) == len(oracle) and sp.tobytes() == oracle
        assert cbor.decode(sp.tobytes()) == cbor.decode(oracle)

    @settings(deadline=None, max_examples=100)
    @given(st.lists(st.integers(0, 10_000), min_size=1, max_size=200))
    def test_property_nack_ranges_roundtrip(indices):
        canonical = tuple(sorted(set(indices)))
        assert ranges_to_missing(missing_to_ranges(indices)) == canonical
        nack = FLChunkNack(MID, 1, 10_001, tuple(indices))
        assert FLChunkNack.from_cbor(nack.to_cbor()).missing == canonical
