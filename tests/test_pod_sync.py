"""§Perf H3: q8 cross-pod gradient sync — numerical validation.

The full mixed manual/auto shard_map hits an XLA SPMD-partitioner CHECK on
this XLA build (documented in EXPERIMENTS.md §Perf H3); the sync itself is
validated here on a small all-manual mesh in a subprocess with 4 host
devices: q8-compressed pod sync must equal the exact mean within blockwise
quantization error, and compress cross-pod bytes ~3.2x.
"""
import subprocess
import sys
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parent.parent

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import _make_mesh
from repro.train.steps import _q8_pod_sync

mesh = _make_mesh((2, 2), ("pod", "data"))

rng = np.random.default_rng(0)
grads = {"w": jnp.asarray(rng.standard_normal((2, 512, 8)) * 0.01,
                          jnp.float32),
         "b": jnp.asarray(rng.standard_normal((2, 16)), jnp.float32)}
# leading dim 2 = per-pod gradient replicas (sharded over "pod")

def sync(g):
    return _q8_pod_sync(g, axis="pod")

if hasattr(jax, "shard_map"):    # jax >= 0.6: top-level API, vma checking
    smap = jax.shard_map(sync, mesh=mesh, in_specs=(P("pod"),),
                         out_specs=P("pod"),
                         axis_names=frozenset({"pod", "data"}),
                         check_vma=False)
else:                            # older jax: experimental API, check_rep
    from jax.experimental.shard_map import shard_map
    smap = shard_map(sync, mesh=mesh, in_specs=(P("pod"),),
                     out_specs=P("pod"), check_rep=False)
synced = jax.jit(smap)(grads)

for k in grads:
    exact = np.asarray(grads[k]).mean(0)
    got = np.asarray(synced[k])[0]  # same on both pods post-sync
    got2 = np.asarray(synced[k])[1]
    np.testing.assert_allclose(got, got2, atol=1e-7)
    bound = np.abs(np.asarray(grads[k])).max() / 127.0 * 0.51 + 1e-7
    np.testing.assert_allclose(got, exact, atol=bound)
print("POD_SYNC_OK")
"""


def test_q8_pod_sync_numerics():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "POD_SYNC_OK" in proc.stdout


def test_q8_pod_sync_traffic_math():
    """Analytic cross-pod accounting used in EXPERIMENTS.md §Perf H3."""
    n_params = 8_537_444_352          # gemma-7b analytic param count
    pods, mb = 2, 4
    # baseline: bf16 ring all-reduce across pods, once per microbatch
    baseline = 2 * (pods - 1) / pods * n_params * 2 * mb
    # optimized: q8 all-gather (1B values + f32/256 scales), once per step
    payload = n_params * (1 + 4 / 256)
    optimized = (pods - 1) / pods * payload
    assert baseline / optimized > 12.5, baseline / optimized
