"""Loss-sweep harness for the symmetric selective-repeat chunk protocol.

Deterministic seeded drop schedules (uniform, bursty, adversarial
single-chunk) are swept over loss rates in both directions (server → client
multicast downlink, client → server unicast uplink), asserting that:

  * every completed transfer reassembles the model byte-identically;
  * retransmitted bytes stay strictly below a monolithic full-stream
    re-send at every non-zero loss rate;
  * random drop / duplicate / reorder / stale schedules can never corrupt
    the assembled parameters (seeded fuzz always; hypothesis when present).
"""
import uuid

import numpy as np
import pytest

from repro.core import cddl, fastpath
from repro.core.messages import FLChunkAck, FLChunkNack, FLModelChunk
from repro.fl.chunking import (
    MAX_REPAIR_WINDOWS,
    AssemblerReceiver,
    ChunkAssembler,
    chunk_stream,
    run_selective_repeat,
)
from repro.fl.server import FLServer, OrchestrationConfig
from repro.transport.network import LossyLink

MID = uuid.UUID(bytes=bytes(range(16)))
LOSS_RATES = [0.0, 0.05, 0.20, 0.40]


def _params(n=20_000, seed=0):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


def _chunks(params, round_=1, elems=1024):
    return list(chunk_stream(MID, round_, params, elems))


# -- seeded drop schedules (chunk_drop hook: (uri, window, index, recv)) ------


def uniform_schedule(rate, seed):
    """Independent per-(window, chunk, receiver) loss at ``rate``."""
    def drop(uri, window, index, receiver):
        return bool(np.random.default_rng(
            (seed, window, index, receiver)).random() < rate)
    return drop


def bursty_schedule(rate, seed, burst=4):
    """Losses arrive in bursts of ``burst`` consecutive chunk indices."""
    def drop(uri, window, index, receiver):
        return bool(np.random.default_rng(
            (seed, window, index // burst, receiver)).random() < rate)
    return drop


def adversarial_schedule(target, windows=1):
    """Exactly chunk ``target`` is lost, for every receiver, for the first
    ``windows`` transfer windows — the worst case for abort-on-failure."""
    def drop(uri, window, index, receiver):
        return window < windows and index == target
    return drop


SCHEDULES = {
    "uniform": lambda rate: uniform_schedule(rate, seed=42),
    "bursty": lambda rate: bursty_schedule(rate, seed=42),
}


def _run(chunks, receivers, schedule, *, multicast=True, **kw):
    link = LossyLink(drop_prob=0.0, seed=1, chunk_drop=schedule)
    report = run_selective_repeat(
        link, chunks, receivers, uri="fl/model/chunk",
        feedback_uri="fl/model/chunk/fb", multicast=multicast, **kw)
    return report


# -- the loss sweep (acceptance criteria) -------------------------------------


@pytest.mark.parametrize("pattern", sorted(SCHEDULES))
@pytest.mark.parametrize("rate", LOSS_RATES)
def test_downlink_sweep_single_receiver(pattern, rate):
    params = _params()
    receivers = [AssemblerReceiver()]
    report = _run(_chunks(params), receivers, SCHEDULES[pattern](rate))
    assert report.completed == [0]
    assert receivers[0].assembled.tobytes() == params.tobytes()
    if rate == 0.0:
        assert report.windows == 1
        assert report.retransmitted_payload_bytes == 0
    else:
        # selective repeat beats a monolithic re-send: everything sent after
        # the first full stream (repairs + control) is less than re-sending
        # the stream even once.
        assert (report.retransmitted_payload_bytes
                + report.control_payload_bytes) < report.initial_payload_bytes


@pytest.mark.parametrize("pattern", sorted(SCHEDULES))
@pytest.mark.parametrize("rate", LOSS_RATES)
def test_downlink_sweep_multicast_three_receivers(pattern, rate):
    params = _params()
    receivers = [AssemblerReceiver() for _ in range(3)]
    report = _run(_chunks(params), receivers, SCHEDULES[pattern](rate))
    assert report.completed == [0, 1, 2]
    for r in receivers:
        assert r.assembled.tobytes() == params.tobytes()
    if rate > 0.0:
        # a full-stream repair scheme re-multicasts everything every window;
        # selective repeat's repair windows send strict subsets.
        full_resend = (report.windows - 1) * report.initial_payload_bytes
        assert report.retransmitted_payload_bytes < full_resend
        assert report.retransmitted_chunks > 0


@pytest.mark.parametrize("pattern", sorted(SCHEDULES))
@pytest.mark.parametrize("rate", LOSS_RATES)
def test_uplink_sweep_into_server_endpoint(pattern, rate):
    """Reverse direction: CON unicast chunks into the server's per-client
    reassembly endpoint, server NACKs the missing set."""
    server = FLServer(OrchestrationConfig(num_clients=2, clients_per_round=2),
                      _params())
    flat = _params(seed=7)
    chunks = list(chunk_stream(server.model_id, server.round, flat, 1024))
    endpoint = server.uplink_endpoint(1)
    report = _run(chunks, [endpoint], SCHEDULES[pattern](rate),
                  multicast=False)
    assert report.completed == [0]
    assert server.pop_uplink(1).tobytes() == flat.tobytes()
    assert server.pop_uplink(1) is None   # state cleared after pop
    if rate > 0.0:
        assert (report.retransmitted_payload_bytes
                + report.control_payload_bytes) < report.initial_payload_bytes


def test_adversarial_single_chunk_loss_costs_one_chunk():
    """The case that used to abort the whole stream: exactly one chunk lost.
    Recovery must cost one repair window and one chunk, not a re-stream."""
    params = _params()
    chunks = _chunks(params)
    receivers = [AssemblerReceiver() for _ in range(2)]
    report = _run(chunks, receivers, adversarial_schedule(target=3))
    assert report.completed == [0, 1]
    for r in receivers:
        assert r.assembled.tobytes() == params.tobytes()
    assert report.windows == 2
    assert report.retransmitted_chunks == 1
    assert report.retransmitted_payload_bytes == len(chunks[3].to_cbor())


def test_persistent_adversary_degrades_to_clean_dropout():
    """A chunk lost in *every* window exhausts the budget: the transfer ends
    incomplete — bounded, uncorrupted, no infinite loop."""
    params = _params(n=4096)
    receivers = [AssemblerReceiver()]
    report = _run(_chunks(params), receivers,
                  adversarial_schedule(target=0, windows=10**9))
    assert report.completed == []
    assert receivers[0].assembled is None
    assert report.windows == 1 + MAX_REPAIR_WINDOWS


def test_lost_feedback_recovers_on_next_window():
    """NACK/ACK messages traverse the lossy link too: losing them costs
    windows, never correctness."""
    params = _params(n=8192)
    receivers = [AssemblerReceiver() for _ in range(2)]
    # chunks delivered deterministically (one loss), control frames lossy
    link = LossyLink(drop_prob=0.6, seed=3,
                     chunk_drop=adversarial_schedule(target=1))
    report = run_selective_repeat(
        link, _chunks(params), receivers, uri="fl/model/chunk",
        feedback_uri="fl/model/chunk/fb", multicast=True)
    assert report.lost_feedback > 0          # seed 3 drops some control msgs
    assert report.completed == [0, 1]
    for r in receivers:
        assert r.assembled.tobytes() == params.tobytes()


# -- reassembly-state unit coverage -------------------------------------------


def test_assembler_duplicates_and_reorder():
    params = _params(n=5000)
    chunks = _chunks(params)
    asm = ChunkAssembler()
    order = [3, 1, 1, 4, 0, 3, 2, 0]   # duplicates + reorder
    done = None
    for i in order:
        out = asm.add(chunks[i])
        done = out if out is not None else done
    assert done is not None
    assert done.tobytes() == params.tobytes()
    assert asm.duplicates == 3
    # a late retransmit of the completed generation is a duplicate, not a
    # fresh assembly
    assert asm.add(chunks[2]) is None
    assert asm.duplicates == 4


def test_assembler_stale_round_rejected_newer_round_resyncs():
    old = _chunks(_params(seed=1), round_=1)
    new_params = _params(seed=2)
    new = _chunks(new_params, round_=2)
    asm = ChunkAssembler()
    assert asm.add(new[0]) is None
    assert asm.add(old[1]) is None          # stale: older round dropped
    assert asm.stale_rejected == 1
    assert asm.missing(MID, 2, len(new)) == list(range(1, len(new)))
    done = None
    for c in new[1:]:
        out = asm.add(c)
        done = out if out is not None else done
    assert done.tobytes() == new_params.tobytes()
    # after completing round 2, round-1 chunks are still stale
    assert asm.add(old[0]) is None
    assert asm.stale_rejected == 2


def test_assembler_crc_rejects_corruption_without_poisoning_state():
    params = _params(n=3000)
    chunks = _chunks(params)
    asm = ChunkAssembler()
    bad = FLModelChunk(chunks[0].model_id, chunks[0].round, 0,
                       chunks[0].num_chunks, chunks[0].crc32,
                       chunks[0].params + 1.0)   # payload no longer matches
    with pytest.raises(ValueError, match="CRC"):
        asm.add(bad)
    done = None
    for c in chunks:
        out = asm.add(c)
        done = out if out is not None else done
    assert done.tobytes() == params.tobytes()


def test_assembler_index_out_of_range():
    c = _chunks(_params(n=100), elems=64)[0]
    asm = ChunkAssembler()
    with pytest.raises(ValueError, match="out of range"):
        asm.add(FLModelChunk(c.model_id, c.round, 5, 2, c.crc32, c.params))


def test_feedback_transitions_nack_to_ack():
    params = _params(n=4000)
    chunks = _chunks(params)
    n = len(chunks)
    asm = ChunkAssembler()
    fb = asm.feedback(MID, 1, n)
    assert isinstance(fb, FLChunkNack) and fb.missing == tuple(range(n))
    asm.add(chunks[2])
    fb = asm.feedback(MID, 1, n)
    assert 2 not in fb.missing and len(fb.missing) == n - 1
    for c in chunks:
        asm.add(c)
    fb = asm.feedback(MID, 1, n)
    assert isinstance(fb, FLChunkAck) and fb.num_chunks == n
    # feedback wire forms validate against their CDDL schemas
    cddl.validate(fastpath.decode(fb.to_cbor()), cddl.SCHEMAS["FL_Chunk_Ack"])


def test_uplink_endpoint_rejects_stale_generation():
    # uplink models are the same size as the global model (the endpoint
    # vouches for that size to bound the gather allocation)
    server = FLServer(OrchestrationConfig(num_clients=1, clients_per_round=1),
                      _params(n=1000))
    flat = _params(n=1000, seed=3)
    stale_round = list(chunk_stream(server.model_id, server.round + 1, flat,
                                    256))
    wrong_model = list(chunk_stream(uuid.uuid4(), server.round, flat, 256))
    ep = server.uplink_endpoint(0)
    assert not ep.receive_chunk(stale_round[0])
    assert not ep.receive_chunk(wrong_model[0])
    assert ep.rejected_stale == 2
    for c in chunk_stream(server.model_id, server.round, flat, 256):
        ep.receive_chunk(c)
    assert server.pop_uplink(0).tobytes() == flat.tobytes()


# -- seeded fuzz: random drop/duplicate/reorder schedules ---------------------


@pytest.mark.parametrize("seed", range(8))
def test_fuzz_schedules_never_corrupt(seed):
    rng = np.random.default_rng(seed)
    params = rng.standard_normal(int(rng.integers(1, 6000))).astype(np.float32)
    elems = int(rng.integers(1, 1500))
    chunks = _chunks(params, elems=elems)
    n = len(chunks)
    stale = _chunks(_params(seed=99), round_=0, elems=elems)
    # delivery sequence: every chunk at least once, plus duplicates, stale
    # traffic from an older round, all in random order
    seq = list(range(n))
    seq += list(rng.integers(0, n, int(rng.integers(0, 2 * n))))   # dups
    rng.shuffle(seq)
    asm = ChunkAssembler()
    done = None
    for idx in seq:
        if rng.random() < 0.3 and stale:
            asm.add(stale[int(rng.integers(0, len(stale)))])
        out = asm.add(chunks[int(idx)])
        done = out if out is not None else done
    assert done is not None
    assert done.tobytes() == params.tobytes()


@pytest.mark.parametrize("seed", range(6))
def test_fuzz_link_schedules_end_to_end(seed):
    """Random chunk_drop tables through the full protocol engine: either a
    clean bounded failure or a byte-identical model — nothing in between."""
    rng = np.random.default_rng((77, seed))
    params = rng.standard_normal(int(rng.integers(100, 8000))
                                 ).astype(np.float32)
    chunks = _chunks(params, elems=int(rng.integers(64, 2048)))
    receivers = [AssemblerReceiver() for _ in range(int(rng.integers(1, 4)))]
    rate = float(rng.uniform(0, 0.6))
    report = _run(chunks, receivers, uniform_schedule(rate, seed=seed))
    for ridx, r in enumerate(receivers):
        if ridx in report.completed:
            assert r.assembled.tobytes() == params.tobytes()
        else:
            assert r.assembled is None
    assert report.windows <= 1 + MAX_REPAIR_WINDOWS


# -- hypothesis property tests (optional dev dep) -----------------------------


try:
    import hypothesis
except ImportError:
    hypothesis = None

if hypothesis is not None:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(deadline=None, max_examples=40)
    @given(st.data())
    def test_property_schedule_never_corrupts(data):
        n_params = data.draw(st.integers(1, 2000), label="n_params")
        elems = data.draw(st.integers(1, 700), label="chunk_elems")
        params = np.arange(n_params, dtype=np.float32)
        chunks = _chunks(params, elems=elems)
        n = len(chunks)
        extra = data.draw(st.lists(st.integers(0, n - 1), max_size=3 * n),
                          label="dups")
        seq = data.draw(st.permutations(list(range(n)) + extra),
                        label="order")
        asm = ChunkAssembler()
        done = None
        for idx in seq:
            out = asm.add(chunks[idx])
            done = out if out is not None else done
        assert done is not None
        assert done.tobytes() == params.tobytes()

    @settings(deadline=None, max_examples=25)
    @given(st.data())
    def test_property_engine_completes_or_fails_clean(data):
        params = np.arange(data.draw(st.integers(64, 2000)),
                           dtype=np.float32)
        chunks = _chunks(params, elems=data.draw(st.integers(32, 512)))
        n = len(chunks)
        table = data.draw(st.dictionaries(
            st.tuples(st.integers(0, 3), st.integers(0, n - 1)),
            st.booleans(), max_size=4 * n), label="drop_table")

        def drop(uri, window, index, receiver):
            return table.get((window, index), False)

        receivers = [AssemblerReceiver()]
        report = _run(chunks, receivers, drop)
        if report.completed:
            assert receivers[0].assembled.tobytes() == params.tobytes()
        else:
            assert receivers[0].assembled is None


# -- wire-level round trips ----------------------------------------------------


def test_nack_ack_wire_roundtrip_and_schema():
    nack = FLChunkNack(MID, 4, 10, (0, 3, 9))
    back = FLChunkNack.from_cbor(nack.to_cbor())
    assert back == nack
    cddl.validate(fastpath.decode(nack.to_cbor()),
                  cddl.SCHEMAS["FL_Chunk_Nack"])
    ack = FLChunkAck(MID, 4, 10)
    assert FLChunkAck.from_cbor(ack.to_cbor()) == ack
    cddl.validate(fastpath.decode(ack.to_cbor()),
                  cddl.SCHEMAS["FL_Chunk_Ack"])
    with pytest.raises(ValueError):
        FLChunkNack(MID, 4, 10, ()).to_cbor()   # empty NACK is an ACK
    with pytest.raises(Exception):
        cddl.validate(fastpath.decode(
            FLChunkAck(MID, 4, 10).to_cbor()), cddl.SCHEMAS["FL_Chunk_Nack"])


# -- duplicate-delivery byte accounting ---------------------------------------


def test_duplicate_delivered_chunks_not_double_counted():
    """A repair multicast reaches *every* receiver, so a re-sent chunk can
    arrive twice (at a receiver that already held it, or one that already
    completed).  The wire accounting must count the repair send once —
    ``retransmitted_payload_bytes`` is bytes on the air, never bytes
    delivered."""
    params = _params(n=10_000)
    chunks = _chunks(params)
    wire_len = {i: len(c.to_cbor()) for i, c in enumerate(chunks)}

    # window 0: receiver 0 misses {3}, receiver 1 misses {7}.  The repair
    # window re-multicasts {3, 7}: chunk 3 arrives a second time at
    # receiver 1 and chunk 7 a second time at receiver 0.
    def drop(uri, window, index, receiver):
        return window == 0 and ((index == 3 and receiver == 0)
                                or (index == 7 and receiver == 1))

    receivers = [AssemblerReceiver(), AssemblerReceiver()]
    report = _run(chunks, receivers, drop)
    assert report.completed == [0, 1]
    for r in receivers:
        assert r.assembled.tobytes() == params.tobytes()
    # both receivers saw exactly one duplicate arrival
    assert receivers[0].assembler.duplicates == 1
    assert receivers[1].assembler.duplicates == 1
    # ...but each repaired chunk is counted exactly once on the wire
    assert report.windows == 2
    assert report.retransmitted_chunks == 2
    assert report.retransmitted_payload_bytes == wire_len[3] + wire_len[7]


def test_resend_into_completed_receiver_counts_once():
    """Seeded schedule where a chunk is repaired for one receiver while the
    other already ACKed the generation: the late duplicate at the completed
    assembler is suppressed, and the repair bytes appear once."""
    params = _params(n=8192)
    chunks = _chunks(params)

    def drop(uri, window, index, receiver):
        return window == 0 and index == 3 and receiver == 0

    receivers = [AssemblerReceiver(), AssemblerReceiver()]
    report = _run(chunks, receivers, drop)
    assert report.completed == [0, 1]
    assert receivers[1].assembler.duplicates == 1   # late repair, completed
    assert report.retransmitted_chunks == 1
    assert report.retransmitted_payload_bytes == len(chunks[3].to_cbor())
    # invariant: payload bytes = the initial full stream + the repairs
    assert report.payload_bytes == \
        report.initial_payload_bytes + report.retransmitted_payload_bytes


def test_repeated_loss_of_same_chunk_counts_each_wire_send():
    """The dual bound: a chunk lost in two consecutive windows costs two
    repair sends — the accounting reports real airtime, not unique chunk
    identities."""
    params = _params(n=8192)
    chunks = _chunks(params)

    def drop(uri, window, index, receiver):
        return window < 2 and index == 5

    receivers = [AssemblerReceiver()]
    report = _run(chunks, receivers, drop)
    assert report.completed == [0]
    assert report.windows == 3
    assert report.retransmitted_chunks == 2         # same chunk, two sends
    assert report.retransmitted_payload_bytes == 2 * len(chunks[5].to_cbor())
