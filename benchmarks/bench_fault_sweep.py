"""Fault-injected round lifecycle: what failures cost on the wire.

Sweeps seeded ``FaultPlan`` chunk-loss rates — with and without a
mid-aggregation server crash — through two deadline-governed FL rounds
(LeNet-5, 4 clients, chunked sequential uplink with medium-aware backoff)
and accounts:

  * rounds-to-quorum — round attempts (crash restarts included) needed
    for two quorum-installed rounds;
  * retransmitted uplink bytes — chunk payload beyond one clean stream
    per fold (selective-repeat repairs + post-crash re-collection);
  * aggregation-snapshot bytes per round — the durability cost of
    crash-recoverable aggregation (fl.round).

Deterministic end to end (seeded plans, seeded link, virtual clock): the
numbers are exact properties of the protocol, not wall-clock noise.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.params_codec import flatten_params
from repro.data import partition_iid, synthetic_mnist
from repro.fl import (
    BackoffPolicy,
    ChunkLoss,
    FaultPlan,
    FLClient,
    FLServer,
    FLSimulation,
    OrchestrationConfig,
    RoundPolicy,
    ServerCrash,
    ServerCrashed,
)
from repro.models import lenet5
from repro.train.optim import SGDConfig

N_CLIENTS = 4
CHUNK_ELEMS = 8192
ROUNDS = 2
POLICY = RoundPolicy(deadline_s=120.0, train_time_s=5.0,
                     backoff=BackoffPolicy(initial_s=0.1))


def _build(tmp_dir: str | None, faults: FaultPlan | None) -> FLSimulation:
    params = lenet5.init_params(jax.random.PRNGKey(0))
    flat, spec = flatten_params(params)
    data = synthetic_mnist(N_CLIENTS * 100, seed=0)
    shards = partition_iid(data, N_CLIENTS, seed=0)
    clients = [FLClient(i, shards[i], lenet5.loss_fn, spec,
                        local_epochs=1, batch_size=32, sgd=SGDConfig(0.05))
               for i in range(N_CLIENTS)]
    cfg = OrchestrationConfig(num_clients=N_CLIENTS,
                              clients_per_round=N_CLIENTS,
                              num_rounds=ROUNDS, min_local_samples=32,
                              checkpoint_dir=tmp_dir)
    return FLSimulation(FLServer(cfg, flat), clients, seed=0,
                        chunk_elems=CHUNK_ELEMS,
                        faults=faults, round_policy=POLICY)


def _scenario(loss_rate: float, crash: bool) -> dict:
    import tempfile

    faults = FaultPlan(
        chunk_loss=ChunkLoss(rate=loss_rate, seed=42) if loss_rate else None,
        server_crashes=(ServerCrash(after_folds=2, at_round=1),)
        if crash else ())
    tmp = tempfile.mkdtemp(prefix="fault_sweep_")
    sim = _build(tmp, faults)
    results, attempts, uplink_payload = [], 0, 0
    while sim.server.round < ROUNDS:
        attempts += 1
        try:
            r = sim.resume_round()
            if r is None:
                r = sim.run_round()
        except ServerCrashed:
            # server restart: fresh process restored from the round
            # checkpoint, resuming from the aggregation snapshot
            uplink_payload += _uplink_payload(sim)
            server = FLServer(sim.server.cfg,
                              np.zeros_like(sim.server.global_params))
            assert server.try_restore()
            sim = FLSimulation(server, list(sim.clients.values()), seed=0,
                               chunk_elems=CHUNK_ELEMS,
                               faults=faults, round_policy=POLICY)
            continue
        results.append(r)
    uplink_payload += _uplink_payload(sim)
    folds = sum(len(r.reporters) for r in results)
    clean_stream_b = _model_payload_bytes(sim)
    return {
        "loss_rate": loss_rate,
        "server_crash": crash,
        "rounds_to_quorum": attempts,
        "quorum_rounds": sum(r.quorum_met for r in results),
        "folds": folds,
        "uplink_payload_B": uplink_payload,
        "retransmitted_B": uplink_payload - folds * clean_stream_b,
        "snapshot_B_per_round": round(
            sum(r.snapshot_bytes for r in results) / max(1, len(results))),
        "round_clock_s": round(sum(r.clock_s for r in results), 3),
    }


def _uplink_payload(sim: FLSimulation) -> int:
    s = sim.accounting.by_type.get("FL_Model_Chunk_Uplink")
    return s.payload_bytes if s else 0


def _model_payload_bytes(sim: FLSimulation) -> int:
    # one clean chunked stream of the model: f32 payload plus per-chunk
    # CBOR headers, measured from an actual chunk stream (exact)
    from repro.core.fastpath import ScatterPayload
    chunks = sim.server.global_update_chunks(CHUNK_ELEMS)
    return sum(len(ScatterPayload(c.to_cbor_segments())) for c in chunks)


def run_json() -> tuple[list[str], dict]:
    rows = ["loss,server_crash,rounds_to_quorum,quorum_rounds,"
            "retransmitted_B,snapshot_B_per_round,round_clock_s"]
    record = {"bench": "fault_sweep", "unit": "bytes", "scenarios": []}
    for loss in (0.0, 0.1, 0.2, 0.3):
        for crash in (False, True):
            m = _scenario(loss, crash)
            record["scenarios"].append(m)
            rows.append(
                f"{m['loss_rate']},{int(m['server_crash'])},"
                f"{m['rounds_to_quorum']},{m['quorum_rounds']},"
                f"{m['retransmitted_B']},{m['snapshot_B_per_round']},"
                f"{m['round_clock_s']}")
    return rows, record


def run() -> list[str]:
    return run_json()[0]


if __name__ == "__main__":
    print("\n".join(run()))
