"""Paper §VI-B2 "message interval": per-round communication burden.

Runs one real FL round (LeNet-5, 8 clients) per configuration and accounts
bytes/frames/airtime per message type over the simulated 802.15.4 link:
  * multicast vs unicast global-model dissemination,
  * f32 vs f16 typed-array model payloads,
  * the large-but-rare (model updates, 1x/round) vs small-but-frequent
    (dataset updates) split the paper argues for.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.messages import ParamsEncoding
from repro.core.params_codec import flatten_params
from repro.data import partition_iid, synthetic_mnist
from repro.fl import FLClient, FLServer, FLSimulation, OrchestrationConfig
from repro.models import lenet5
from repro.train.optim import SGDConfig
from repro.transport.network import LossyLink


def _one_round(encoding: ParamsEncoding, multicast: bool) -> dict:
    params = lenet5.init_params(jax.random.PRNGKey(0))
    flat, spec = flatten_params(params)
    data = synthetic_mnist(8 * 100, seed=0)
    shards = partition_iid(data, 8, seed=0)
    clients = [FLClient(i, shards[i], lenet5.loss_fn, spec,
                        local_epochs=1, batch_size=32, sgd=SGDConfig(0.05))
               for i in range(8)]
    cfg = OrchestrationConfig(num_clients=8, clients_per_round=8,
                              num_rounds=1, min_local_samples=32,
                              params_encoding=encoding)
    sim = FLSimulation(FLServer(cfg, flat), clients,
                       multicast_global=multicast)
    sim.run_round()
    return sim.accounting.by_type


def run() -> list[str]:
    rows = ["config,message,messages,blocks,frames,payload_B,link_B,"
            "airtime_s"]
    for enc, mc in ((ParamsEncoding.TA_F32, False),
                    (ParamsEncoding.TA_F32, True),
                    (ParamsEncoding.TA_F16, True)):
        name = f"{enc.value}_{'multicast' if mc else 'unicast'}"
        acc = _one_round(enc, mc)
        for mtype, s in sorted(acc.items()):
            rows.append(
                f"{name},{mtype},{s.messages},{s.blocks},{s.frames},"
                f"{s.payload_bytes},{s.link_bytes},"
                f"{LossyLink.airtime_seconds(s):.3f}")
    return rows


def run_uplink_airtime() -> list[str]:
    """Shared-medium uplink: sequential vs interleaved round airtime.

    All clients upload a 20k-param f32 model through the selective-repeat
    chunk protocol over ONE contention domain (docs/concurrent_uplink.md).
    Sequential schedules pay every feedback-turnaround gap serially;
    interleaving fills one client's gap with another client's frames, so
    round airtime approaches the busy floor.  Deterministic (virtual
    clock + seeded medium) — the speedup column is exact, not wall-clock.
    """
    import uuid

    from repro.fl.chunking import (
        AssemblerReceiver,
        UplinkSession,
        chunk_stream,
        run_interleaved_uplinks,
    )
    from repro.transport.medium import SharedMedium

    n_params, chunk_elems = 20_000, 2048
    mid = uuid.UUID(int=0x5eed)

    def chunk_drop(rate):
        # seeded per-(window, chunk, client) verdicts: BOTH modes lose the
        # exact same chunks, so the airtime delta is purely scheduling
        def drop(uri, window, index, client):
            return bool(np.random.default_rng(
                (99, window, index, client)).random() < rate)
        return drop

    rows = ["clients,loss,mode,airtime_s,busy_s,idle_s,windows,frames,"
            "speedup"]
    for n_clients in (1, 2, 4, 8):
        for drop in (0.0, 0.10):
            airtime = {}
            for sequential in (True, False):
                medium = SharedMedium(seed=0, reorder_prob=0.1,
                                      turnaround_s=0.5,
                                      chunk_drop=chunk_drop(drop))
                sessions = []
                for c in range(n_clients):
                    params = np.random.default_rng(c).standard_normal(
                        n_params).astype(np.float32)
                    sessions.append(UplinkSession(
                        c, list(chunk_stream(mid, 1, params, chunk_elems)),
                        AssemblerReceiver(expected_elems=n_params)))
                rep = run_interleaved_uplinks(medium, sessions,
                                              sequential=sequential)
                assert all(s.report.completed == [0] for s in sessions)
                mode = "sequential" if sequential else "interleaved"
                airtime[mode] = rep.airtime_s
                rows.append(
                    f"{n_clients},{drop},{mode},{rep.airtime_s:.3f},"
                    f"{rep.busy_s:.3f},{rep.idle_s:.3f},"
                    f"{sum(s.report.windows for s in sessions)},"
                    f"{rep.stats.frames},"
                    f"{airtime['sequential'] / rep.airtime_s:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
