"""Paper §VI-B2 "message interval": per-round communication burden.

Runs one real FL round (LeNet-5, 8 clients) per configuration and accounts
bytes/frames/airtime per message type over the simulated 802.15.4 link:
  * multicast vs unicast global-model dissemination,
  * f32 vs f16 typed-array model payloads,
  * the large-but-rare (model updates, 1x/round) vs small-but-frequent
    (dataset updates) split the paper argues for.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.messages import ParamsEncoding
from repro.core.params_codec import flatten_params
from repro.data import partition_iid, synthetic_mnist
from repro.fl import FLClient, FLServer, FLSimulation, OrchestrationConfig
from repro.models import lenet5
from repro.train.optim import SGDConfig
from repro.transport.network import LossyLink


def _one_round(encoding: ParamsEncoding, multicast: bool) -> dict:
    params = lenet5.init_params(jax.random.PRNGKey(0))
    flat, spec = flatten_params(params)
    data = synthetic_mnist(8 * 100, seed=0)
    shards = partition_iid(data, 8, seed=0)
    clients = [FLClient(i, shards[i], lenet5.loss_fn, spec,
                        local_epochs=1, batch_size=32, sgd=SGDConfig(0.05))
               for i in range(8)]
    cfg = OrchestrationConfig(num_clients=8, clients_per_round=8,
                              num_rounds=1, min_local_samples=32,
                              params_encoding=encoding)
    sim = FLSimulation(FLServer(cfg, flat), clients,
                       multicast_global=multicast)
    sim.run_round()
    return sim.accounting.by_type


def run() -> list[str]:
    rows = ["config,message,messages,blocks,frames,payload_B,link_B,"
            "airtime_s"]
    for enc, mc in ((ParamsEncoding.TA_F32, False),
                    (ParamsEncoding.TA_F32, True),
                    (ParamsEncoding.TA_F16, True)):
        name = f"{enc.value}_{'multicast' if mc else 'unicast'}"
        acc = _one_round(enc, mc)
        for mtype, s in sorted(acc.items()):
            rows.append(
                f"{name},{mtype},{s.messages},{s.blocks},{s.frames},"
                f"{s.payload_bytes},{s.link_bytes},"
                f"{LossyLink.airtime_seconds(s):.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
