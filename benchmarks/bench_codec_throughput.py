"""Codec throughput: encode/decode µs per model size, plus BENCH_codec.json.

Compares the paths that exist in the system:
  * python_ref    — the pure-Python CBOR item encoder (oracle)
  * numpy_ta      — message encode via the contiguous fast path (one
                    payload copy into the preallocated buffer + finalize)
  * encode_vectored — scatter-gather message encode: owned header segments
                    + borrowed payload views, zero payload copies
  * decode_seed   — the seed decode chain: recursive oracle decode (payload
                    sliced to fresh bytes) + a ``bytes()`` copy before
                    ``np.frombuffer`` — kept inline as the baseline the
                    ISSUE's ≥3x decode criterion is measured against
  * decode_fastpath — iterative memoryview decode, ``np.frombuffer`` on the
                    zero-copy payload view
  * decode_segments — the segmented receive path: the same decode walking a
                    ``ScatterPayload``'s segment chain without joining it;
                    the params payload lands contiguous in one segment and
                    comes back as a borrowed view
  * decode_ring   — the *production* receive shape: ≤64 B blockwise
                    deliveries coalesced into a ``BlockReceiveRing`` arena,
                    decoded as borrowed views of the ring's own memory
  * pallas_f16    — the quantize_f16 kernel path emitting owned ``bytes``
                    (interpret mode on CPU; on TPU this is the compiled
                    VMEM-tiled kernel)
  * pallas_f16_vec — the same kernel handing the wire a borrowed view,
                    spliced into a vectored message (no ``bytes`` handoff)
  * q8_kernel     — blockwise int8 compression kernel

``run()`` prints the CSV section; ``run_json()`` additionally returns the
machine-readable record (encode/decode MB/s, tracemalloc peak bytes, and a
``copies_per_roundtrip`` counter per model size) that ``benchmarks/run.py``
writes to ``BENCH_codec.json`` so the perf trajectory is tracked PR over PR.

``copies_per_roundtrip`` is measured, not asserted: tracemalloc peak bytes
of one encode + one decode divided by the payload size — ~2 for the
contiguous encode chain (encode buffer + finalize), ~0 for the vectored
chain (headers only on encode, views only on decode).
"""
from __future__ import annotations

import time
import tracemalloc
import uuid

import numpy as np

from repro.core import cbor, fastpath
from repro.core.messages import FLGlobalModelUpdate, ParamsEncoding
from repro.core.typed_arrays import decode_typed_array

UUID = uuid.UUID(bytes=bytes(range(16)))
SIZES = [1000, 10_000, 44_426, 1_000_000]


def _time(fn, repeats=9) -> float:
    """Best-of-N µs per call.  The minimum (not the mean) is the standard
    microbenchmark statistic: scheduler preemption and allocator jitter
    only ever add time, so min-of-N converges on the true cost and keeps
    the tier-2 trend gate from flapping on loaded boxes."""
    fn()  # warmup / jit
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _peak_alloc(fn) -> int:
    tracemalloc.start()
    fn()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return peak


def _decode_seed(data: bytes) -> np.ndarray:
    """The seed decode chain, verbatim: oracle decode (payload slice copy)
    then a bytes() round-trip into np.frombuffer (second copy)."""
    item = cbor.decode(data)
    ta = item[2]
    return np.frombuffer(bytes(ta.value), dtype="<f4")


def _decode_fastpath(data: bytes) -> np.ndarray:
    item = fastpath.decode(data)
    return decode_typed_array(item[2])


def _decode_segments(source) -> np.ndarray:
    item = fastpath.decode(source)      # segment cursor, no join
    return decode_typed_array(item[2])


def _ring_of(wire: bytes, block: int = 64):
    """The production receive shape: ≤64 B blockwise deliveries coalesced
    into a BlockReceiveRing's arena segments."""
    from repro.transport.coap import BlockReceiveRing

    ring = BlockReceiveRing()
    for i in range(0, len(wire), block):
        ring.add_block(wire[i : i + block])
    return ring


def _assemble_chunked(chunks) -> np.ndarray:
    """Receive side of a chunked transfer: gather every chunk payload into
    the assembler's preallocated model buffer (peak = model + O(chunk))."""
    from repro.fl.chunking import ChunkAssembler

    asm = ChunkAssembler()
    out = None
    for c in chunks:
        flat = asm.add(c)
        if flat is not None:
            out = flat
    return out


def _paths(n: int, flat: np.ndarray, msg: FLGlobalModelUpdate,
           wire_f32: bytes, sp_f32: fastpath.ScatterPayload, ring_f32,
           jflat) -> dict:
    from repro.kernels.q8_block.ops import compress_update
    from repro.kernels.quantize_f16.ops import (
        params_to_f16_payload,
        params_to_f16_view,
    )

    return {
        "python_ref_dynamic": (lambda: cbor.encode(
            [float(v) for v in flat[: min(n, 10_000)]]),
            min(n, 10_000) * 4),
        "numpy_ta_f16": (lambda: msg.to_cbor(ParamsEncoding.TA_F16), n * 4),
        "numpy_ta_f32": (lambda: msg.to_cbor(ParamsEncoding.TA_F32), n * 4),
        "encode_vectored_f32": (
            lambda: msg.to_cbor_segments(ParamsEncoding.TA_F32), n * 4),
        "decode_seed_f32": (lambda: _decode_seed(wire_f32), n * 4),
        "decode_fastpath_f32": (lambda: _decode_fastpath(wire_f32), n * 4),
        "decode_segments_f32": (lambda: _decode_segments(sp_f32), n * 4),
        "decode_ring_f32": (lambda: _decode_segments(ring_f32), n * 4),
        "pallas_f16": (lambda: params_to_f16_payload(jflat), n * 4),
        "pallas_f16_vec": (lambda: msg.to_cbor_segments(
            ParamsEncoding.TA_F16,
            params_payload=params_to_f16_view(jflat)), n * 4),
        "q8_kernel": (lambda: compress_update(jflat), n * 4),
    }


def run_json() -> tuple[list[str], dict]:
    """-> (CSV rows, BENCH_codec.json record)."""
    import jax.numpy as jnp

    rows = ["path,model_size,us_per_call,derived_MBps"]
    record: dict = {"bench": "codec_throughput", "unit": "MB/s", "sizes": {}}
    rng = np.random.default_rng(0)
    for n in SIZES:
        flat = rng.standard_normal(n).astype(np.float32)
        jflat = jnp.asarray(flat)
        msg = FLGlobalModelUpdate(UUID, 1, flat, True)
        wire_f32 = msg.to_cbor(ParamsEncoding.TA_F32)
        sp_f32 = fastpath.ScatterPayload(
            msg.to_cbor_segments(ParamsEncoding.TA_F32))
        ring_f32 = _ring_of(wire_f32)

        entry: dict = {"bytes_f32_payload": n * 4}
        for name, (fn, nbytes) in _paths(n, flat, msg, wire_f32, sp_f32,
                                         ring_f32, jflat).items():
            us = _time(fn)
            rows.append(f"{name},{n},{us:.1f},{nbytes / us:.1f}")
            entry[name] = {"us_per_call": round(us, 1),
                           "MBps": round(nbytes / us, 1)}
        entry["speedup_decode_fastpath_vs_seed"] = round(
            entry["decode_seed_f32"]["us_per_call"]
            / entry["decode_fastpath_f32"]["us_per_call"], 2)
        entry["speedup_decode_segments_vs_seed"] = round(
            entry["decode_seed_f32"]["us_per_call"]
            / entry["decode_segments_f32"]["us_per_call"], 2)
        entry["speedup_encode_vectored_vs_contiguous"] = round(
            entry["numpy_ta_f32"]["us_per_call"]
            / entry["encode_vectored_f32"]["us_per_call"], 2)
        # peak allocations: "fastpath" tracks the production wire path —
        # since the vectored refactor that is the scatter-gather encoder
        # (headers only); the contiguous single-buffer path stays recorded
        # for comparison.
        peak_enc_vec = _peak_alloc(
            lambda: msg.to_cbor_segments(ParamsEncoding.TA_F32))
        peak_enc_contig = _peak_alloc(
            lambda: msg.to_cbor(ParamsEncoding.TA_F32))
        peak_dec = _peak_alloc(lambda: _decode_fastpath(wire_f32))
        entry["peak_alloc_encode_fastpath"] = peak_enc_vec
        entry["peak_alloc_encode_contiguous"] = peak_enc_contig
        entry["peak_alloc_decode_seed"] = _peak_alloc(
            lambda: _decode_seed(wire_f32))
        entry["peak_alloc_decode_fastpath"] = peak_dec
        # receiver peak of a full chunked transfer: the gather assembler
        # allocates one model buffer and writes each chunk into its slot,
        # so this stays ≈ bytes_f32_payload + O(chunk), not 2× model.
        from repro.fl.chunking import chunk_stream
        chunks = list(chunk_stream(UUID, 1, flat, 4096))
        _assemble_chunked(chunks)  # warmup
        entry["peak_alloc_decode_chunked"] = _peak_alloc(
            lambda: _assemble_chunked(chunks))
        entry["copies_per_roundtrip"] = {
            "contiguous": round((peak_enc_contig + peak_dec) / (n * 4), 2),
            "vectored": round((peak_enc_vec + peak_dec) / (n * 4), 2),
        }
        record["sizes"][str(n)] = entry
    return rows, record


def run() -> list[str]:
    rows, _ = run_json()
    return rows


if __name__ == "__main__":
    import json

    rows, record = run_json()
    print("\n".join(rows))
    print(json.dumps(record, indent=2))
