"""Codec throughput: encode/decode µs per model size.

Compares the paths that exist in the system:
  * python_ref    — the pure-Python CBOR item encoder (oracle)
  * numpy_ta      — vectorized typed-array payload (np.astype + tobytes)
  * pallas_f16    — the quantize_f16 kernel path (interpret mode on CPU;
                    on TPU this is the compiled VMEM-tiled kernel)
  * q8_kernel     — blockwise int8 compression kernel
"""
from __future__ import annotations

import time
import uuid

import jax.numpy as jnp
import numpy as np

from repro.core import cbor
from repro.core.messages import FLGlobalModelUpdate, ParamsEncoding
from repro.kernels.q8_block.ops import compress_update
from repro.kernels.quantize_f16.ops import params_to_f16_payload

UUID = uuid.UUID(bytes=bytes(range(16)))
SIZES = [1000, 10_000, 44_426, 1_000_000]


def _time(fn, repeats=5) -> float:
    fn()  # warmup / jit
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6


def run() -> list[str]:
    rows = ["path,model_size,us_per_call,derived_MBps"]
    rng = np.random.default_rng(0)
    for n in SIZES:
        flat = rng.standard_normal(n).astype(np.float32)
        jflat = jnp.asarray(flat)
        msg = FLGlobalModelUpdate(UUID, 1, flat, True)

        paths = {
            "python_ref_dynamic": (lambda: cbor.encode(
                [float(v) for v in flat[: min(n, 10_000)]]),
                min(n, 10_000) * 4),
            "numpy_ta_f16": (lambda: msg.to_cbor(ParamsEncoding.TA_F16),
                             n * 4),
            "numpy_ta_f32": (lambda: msg.to_cbor(ParamsEncoding.TA_F32),
                             n * 4),
            "pallas_f16": (lambda: params_to_f16_payload(jflat), n * 4),
            "q8_kernel": (lambda: compress_update(jflat), n * 4),
        }
        for name, (fn, nbytes) in paths.items():
            us = _time(fn)
            rows.append(f"{name},{n},{us:.1f},{nbytes / us:.1f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
