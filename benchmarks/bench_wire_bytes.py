"""Wire bytes per round for each chunk encoding — the compression table.

One "round" of uplink traffic is a full chunked model transfer: the sum of
every chunk's vectored wire form (headers + borrowed payload segments,
``ScatterPayload`` length — exactly what the CoAP framer puts on the
medium before link overhead).  Measured per encoding at the LeNet-5 size
(44 426 params) and at 1 M params:

  * f32          — ta-float32le chunk payloads (the baseline)
  * f16          — ta-float16le payloads (error feedback on the client)
  * q8           — q8-block payloads (int8 values + per-256-block scales)
  * q8-residual  — the same q8 wire format carrying ``local − last_global``
                   deltas; byte-wise identical cost, listed so the table
                   states explicitly that residual mode changes *what* the
                   bytes mean, not how many there are.

``run_json()`` returns the CSV rows plus the ``wire_bytes_per_round``
record that ``benchmarks/run.py`` merges into BENCH_codec.json; the
``--check`` gate asserts the q8 ratio stays ≤ 0.30× f32 (the acceptance
bound: 1 byte + 2 scale bytes per 256 elems ≈ 0.254× of 4 bytes/elem).
"""
from __future__ import annotations

import uuid

import numpy as np

from repro.core import fastpath
from repro.core.messages import ParamsEncoding
from repro.fl.chunking import chunk_stream

UUID = uuid.UUID(bytes=bytes(range(16)))
SIZES = [44_426, 1_000_000]     # LeNet-5 (paper table 2) and 1M params
CHUNK_ELEMS = 8192              # 32 KiB f32 chunks, % Q8_BLOCK == 0
Q8_MAX_RATIO = 0.30             # acceptance bound, gated by run.py --check

MODES = [
    ("f32", ParamsEncoding.TA_F32, False),
    ("f16", ParamsEncoding.TA_F16, False),
    ("q8", ParamsEncoding.Q8, False),
    ("q8-residual", ParamsEncoding.Q8, True),
]


def _wire_bytes_per_round(flat: np.ndarray, encoding: ParamsEncoding,
                          residual: bool) -> tuple[int, int]:
    """-> (total wire bytes, num chunks) for one full chunked transfer."""
    if residual:
        # a residual uplink quantizes ``local − global``: small-magnitude
        # values, same element count — the wire cost is what's measured
        flat = flat * 0.01
    chunks = list(chunk_stream(UUID, 1, flat, CHUNK_ELEMS,
                               encoding=encoding))
    total = sum(len(fastpath.ScatterPayload(c.to_cbor_segments()))
                for c in chunks)
    return total, len(chunks)


def run_json() -> tuple[list[str], dict]:
    """-> (CSV rows, the ``wire_bytes_per_round`` BENCH_codec.json record)."""
    rows = ["mode,model_size,num_chunks,wire_bytes_per_round,"
            "bytes_per_param,ratio_vs_f32"]
    record: dict = {"unit": "bytes", "chunk_elems": CHUNK_ELEMS,
                    "q8_max_ratio": Q8_MAX_RATIO, "sizes": {}}
    rng = np.random.default_rng(0)
    for n in SIZES:
        flat = rng.standard_normal(n).astype(np.float32)
        entry: dict = {}
        f32_total = None
        for mode, encoding, residual in MODES:
            total, num = _wire_bytes_per_round(flat, encoding, residual)
            if mode == "f32":
                f32_total = total
            ratio = total / f32_total
            rows.append(f"{mode},{n},{num},{total},{total / n:.3f},"
                        f"{ratio:.3f}")
            entry[mode] = {"wire_bytes": total, "num_chunks": num,
                           "bytes_per_param": round(total / n, 3),
                           "ratio_vs_f32": round(ratio, 3)}
        record["sizes"][str(n)] = entry
    return rows, record


def run() -> list[str]:
    rows, _ = run_json()
    return rows


if __name__ == "__main__":
    import json

    rows, record = run_json()
    print("\n".join(rows))
    print(json.dumps(record, indent=2))
