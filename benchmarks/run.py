# One function per paper table. Prints CSV sections; also writes
# BENCH_codec.json (codec MB/s + peak allocations) so the serialization
# perf trajectory is tracked from PR to PR.
from __future__ import annotations

import json
import time
from pathlib import Path


def main() -> None:
    from benchmarks import (
        bench_codec_throughput,
        bench_fl_round,
        bench_lenet,
        bench_message_sizes,
    )

    def codec_run():
        rows, record = bench_codec_throughput.run_json()
        out = Path(__file__).resolve().parent.parent / "BENCH_codec.json"
        out.write_text(json.dumps(record, indent=2) + "\n")
        rows.append(f"# wrote {out}")
        return rows

    sections = [
        ("table1_message_sizes", bench_message_sizes.run),
        ("table2_lenet5", bench_lenet.run),
        ("codec_throughput", codec_run),
        ("fl_round_accounting", bench_fl_round.run),
    ]
    for name, fn in sections:
        t0 = time.time()
        rows = fn()
        dt = time.time() - t0
        print(f"## {name} ({dt:.1f}s)")
        print("\n".join(rows))
        print()
    print("## roofline")
    print("see reports/roofline.json + EXPERIMENTS.md §Roofline "
          "(derived from the dry-run artifacts, not wall-clock)")


if __name__ == "__main__":
    main()
