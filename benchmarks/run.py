# One function per paper table. Prints CSV sections.
from __future__ import annotations

import time


def main() -> None:
    from benchmarks import (
        bench_codec_throughput,
        bench_fl_round,
        bench_lenet,
        bench_message_sizes,
    )

    sections = [
        ("table1_message_sizes", bench_message_sizes.run),
        ("table2_lenet5", bench_lenet.run),
        ("codec_throughput", bench_codec_throughput.run),
        ("fl_round_accounting", bench_fl_round.run),
    ]
    for name, fn in sections:
        t0 = time.time()
        rows = fn()
        dt = time.time() - t0
        print(f"## {name} ({dt:.1f}s)")
        print("\n".join(rows))
        print()
    print("## roofline")
    print("see reports/roofline.json + EXPERIMENTS.md §Roofline "
          "(derived from the dry-run artifacts, not wall-clock)")


if __name__ == "__main__":
    main()
