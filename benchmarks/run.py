# One function per paper table. Prints CSV sections; also writes
# BENCH_codec.json (codec MB/s + peak allocations + copies_per_roundtrip)
# so the serialization perf trajectory is tracked from PR to PR.
#
# `--check` compares a fresh codec run against the committed
# BENCH_codec.json and exits non-zero on a >2x decode- OR
# encode-throughput regression — the PR-over-PR trend gate (run via the
# tier-2 pytest marker: `pytest -m tier2`).
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
if str(_REPO) not in sys.path:   # `python benchmarks/run.py` from anywhere
    sys.path.insert(0, str(_REPO))

BENCH_JSON = _REPO / "BENCH_codec.json"
DECODE_PATHS = ("decode_fastpath_f32", "decode_segments_f32",
                "decode_ring_f32", "decode_seed_f32")
ENCODE_PATHS = ("encode_vectored_f32", "numpy_ta_f32")
REGRESSION_FACTOR = 2.0


def check(factor: float = REGRESSION_FACTOR,
          out: str | None = None) -> int:
    """Fresh codec bench vs committed BENCH_codec.json.

    Returns 0 when every decode and encode path is within ``factor`` of
    the committed throughput, 1 on a regression (or a missing/malformed
    committed record).  ``out`` writes the fresh record to a file *before*
    comparing — CI uploads it as an artifact whether the gate passes or
    not, without paying for a second bench run.
    """
    from benchmarks import bench_codec_throughput, bench_wire_bytes

    if not BENCH_JSON.exists():
        print(f"check: no committed record at {BENCH_JSON}")
        return 1
    committed = json.loads(BENCH_JSON.read_text())
    _, fresh = bench_codec_throughput.run_json()
    _, wire = bench_wire_bytes.run_json()
    fresh["wire_bytes_per_round"] = wire
    if out:
        Path(out).write_text(json.dumps(fresh, indent=2) + "\n")
        print(f"check: wrote fresh record to {out}")
    failures = {"decode": [], "encode": []}
    compared = 0
    for size, entry in committed.get("sizes", {}).items():
        for kind, names in (("decode", DECODE_PATHS),
                            ("encode", ENCODE_PATHS)):
            for name in names:
                old = entry.get(name, {}).get("MBps")
                new = fresh["sizes"].get(size, {}).get(name, {}).get("MBps")
                if not old or not new:
                    continue
                compared += 1
                if new * factor < old:
                    failures[kind].append(
                        f"  {name} @ {size} params: {old:.1f} -> {new:.1f} "
                        f"MB/s ({old / new:.1f}x slower)")
    if compared == 0:
        print("check: committed record has no comparable codec entries")
        return 1
    failed = False
    # compression acceptance: q8 chunks must stay within the wire-bytes
    # bound of f32 (deterministic — re-measured fresh, no baseline drift)
    for size, entry in wire["sizes"].items():
        ratio = entry["q8"]["ratio_vs_f32"]
        if ratio > bench_wire_bytes.Q8_MAX_RATIO:
            failed = True
            print(f"check: q8 wire bytes @ {size} params = {ratio:.3f}x "
                  f"f32, above the {bench_wire_bytes.Q8_MAX_RATIO}x bound")
    for kind, lines in failures.items():
        if lines:
            failed = True
            print(f"check: {kind} throughput regressed >{factor}x:")
            print("\n".join(lines))
    if failed:
        return 1
    print(f"check: OK ({compared} codec entries within {factor}x "
          "of committed BENCH_codec.json)")
    return 0


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--check", action="store_true",
                        help="compare a fresh codec bench against the "
                             "committed BENCH_codec.json; exit 1 on >2x "
                             "decode-throughput regression")
    parser.add_argument("--factor", type=float, default=REGRESSION_FACTOR,
                        help="regression factor for --check (default "
                             f"{REGRESSION_FACTOR}; CI uses a looser bound "
                             "because the committed baseline was measured "
                             "on different hardware)")
    parser.add_argument("--out", default=None,
                        help="with --check: also write the freshly "
                             "measured record to this path (written before "
                             "the comparison, so a failing gate still "
                             "produces the artifact)")
    args = parser.parse_args()
    if args.check:
        return check(args.factor, args.out)

    from benchmarks import (
        bench_codec_throughput,
        bench_fault_sweep,
        bench_fl_round,
        bench_lenet,
        bench_message_sizes,
        bench_scale,
        bench_wire_bytes,
    )

    def _merge_into_bench_json(update: dict) -> None:
        # BENCH_codec.json carries sections from more than one bench; a
        # re-run of one section must never clobber the committed numbers
        # of another (the codec baseline was measured on dev hardware)
        record = (json.loads(BENCH_JSON.read_text())
                  if BENCH_JSON.exists() else {})
        record.update(update)
        BENCH_JSON.write_text(json.dumps(record, indent=2) + "\n")

    def codec_run():
        rows, record = bench_codec_throughput.run_json()
        _merge_into_bench_json(record)
        rows.append(f"# wrote {BENCH_JSON}")
        return rows

    def fault_sweep_run():
        rows, record = bench_fault_sweep.run_json()
        _merge_into_bench_json({"fault_sweep": record})
        rows.append(f"# merged fault_sweep into {BENCH_JSON}")
        return rows

    def wire_bytes_run():
        rows, record = bench_wire_bytes.run_json()
        _merge_into_bench_json({"wire_bytes_per_round": record})
        rows.append(f"# merged wire_bytes_per_round into {BENCH_JSON}")
        return rows

    def scale_run():
        rows, record = bench_scale.run_json()
        _merge_into_bench_json({"scale_rounds": record})
        rows.append(f"# merged scale_rounds into {BENCH_JSON}")
        return rows

    sections = [
        ("table1_message_sizes", bench_message_sizes.run),
        ("table2_lenet5", bench_lenet.run),
        ("codec_throughput", codec_run),
        ("wire_bytes_per_round", wire_bytes_run),
        ("fl_round_accounting", bench_fl_round.run),
        ("uplink_airtime_shared_medium", bench_fl_round.run_uplink_airtime),
        ("fault_sweep", fault_sweep_run),
        ("scale_rounds", scale_run),
    ]
    for name, fn in sections:
        t0 = time.time()
        rows = fn()
        dt = time.time() - t0
        print(f"## {name} ({dt:.1f}s)")
        print("\n".join(rows))
        print()
    print("## roofline")
    print("see reports/roofline.json + EXPERIMENTS.md §Roofline "
          "(derived from the dry-run artifacts, not wall-clock)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
