"""Paper Table II: real-world LeNet-5 (44,426 params) message sizes.

Unlike Table I (value 1.0 everywhere = JSON best case), this uses real
initialized weights — the paper's "average case with real-world values",
where it reports CBOR at ~24 % of JSON.  We measure CBOR f16 and f32
typed arrays, dynamic CBOR, Protobuf and JSON, plus the beyond-paper
q8-compressed payload."""
from __future__ import annotations

import uuid

import jax
import numpy as np

from repro.core.messages import (
    FLGlobalModelUpdate,
    FLLocalModelUpdate,
    ModelMetadata,
    ParamsEncoding,
)
from repro.core.params_codec import encode_q8, flatten_params
from repro.models import lenet5

UUID = uuid.UUID(bytes=bytes(range(16)))
PAPER_PROTOBUF = {"FL_Global_Model_Update": 177_730,
                  "FL_Local_Model_Update": 177_748}


def run() -> list[str]:
    params = lenet5.init_params(jax.random.PRNGKey(0))
    flat, _ = flatten_params(params)
    assert flat.size == lenet5.PARAM_COUNT == 44_426
    rows = ["message,encoding,bytes,vs_json_pct,paper_match"]
    for name, msg in (
        ("FL_Global_Model_Update",
         FLGlobalModelUpdate(UUID, 1, flat, True)),
        ("FL_Local_Model_Update",
         FLLocalModelUpdate(UUID, 1, flat, ModelMetadata(0.31, 0.29))),
    ):
        json_size = len(msg.to_json())
        pb = len(msg.to_protobuf())
        match = ("exact" if pb == PAPER_PROTOBUF[name]
                 else f"off_by_{pb - PAPER_PROTOBUF[name]}")
        entries = [
            ("json", json_size),
            ("protobuf", pb),
            ("cbor_dynamic", len(msg.to_cbor(ParamsEncoding.DYNAMIC))),
            ("cbor_ta_f32", len(msg.to_cbor(ParamsEncoding.TA_F32))),
            ("cbor_ta_f16", len(msg.to_cbor(ParamsEncoding.TA_F16))),
        ]
        q8_payload, _ = encode_q8(flat)
        entries.append(("cbor_q8_beyond_paper",
                        len(q8_payload) + 22))  # + envelope overhead
        for enc, size in entries:
            pm = match if enc == "protobuf" else ""
            rows.append(f"{name},{enc},{size},"
                        f"{100.0 * size / json_size:.1f},{pm}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
