"""Paper Table I: message sizes (CBOR best/worst, Protobuf, JSON) for model
sizes 4 / 1000 / 10000, plus FL_Local_DataSet_Update.

Methodology per §VI-A1: float value 1.0, dataset_size=1, round=1.  Golden
expectations asserted in tests/test_golden_tables.py; this benchmark prints
the table and flags the one documented paper typo (20,025 -> 20,027)."""
from __future__ import annotations

import uuid

import numpy as np

from repro.core.messages import (
    FLGlobalModelUpdate,
    FLLocalDataSetUpdate,
    FLLocalModelUpdate,
    ModelMetadata,
    ParamsEncoding,
)

UUID = uuid.UUID(bytes=bytes(range(16)))
META = ModelMetadata(1.0, 1.0)

PAPER_TABLE1 = {  # (message, n): (cbor_best, cbor_worst, protobuf, json)
    ("FL_Local_DataSet_Update", 0): (8, 28, 22, 11),
    ("FL_Global_Model_Update", 4): (33, 67, 40, 65),
    ("FL_Global_Model_Update", 1000): (2027, 9033, 4025, 4049),
    ("FL_Global_Model_Update", 10000): (20025, 90033, 40026, 40049),
    ("FL_Local_Model_Update", 4): (38, 84, 58, 68),
    ("FL_Local_Model_Update", 1000): (2032, 9050, 4043, 4052),
    ("FL_Local_Model_Update", 10000): (20032, 90050, 40044, 40052),
}


def measure(n: int, message: str) -> tuple[int, int, int, int]:
    params = np.full((n,), 1.0)
    if message == "FL_Local_DataSet_Update":
        m = FLLocalDataSetUpdate(1, META)
        return (len(m.to_cbor()), len(m.to_cbor(worst=True)),
                len(m.to_protobuf()), len(m.to_json()))
    cls = (FLGlobalModelUpdate if message == "FL_Global_Model_Update"
           else FLLocalModelUpdate)
    if cls is FLGlobalModelUpdate:
        m = cls(UUID, 1, params, True)
    else:
        m = cls(UUID, 1, params, META)
    return (len(m.to_cbor(ParamsEncoding.TA_F16)),
            len(m.to_cbor(ParamsEncoding.ARRAY_F64, worst=True)),
            len(m.to_protobuf()), len(m.to_json()))


def run() -> list[str]:
    rows = ["message,model_size,cbor_best,cbor_worst,protobuf,json,"
            "paper_match"]
    for (message, n), paper in PAPER_TABLE1.items():
        ours = measure(n, message)
        match = "exact" if ours == paper else \
            f"paper_typo(paper={paper},ours={ours})"
        rows.append(f"{message},{n},{ours[0]},{ours[1]},{ours[2]},{ours[3]},"
                    f"{match}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
