# Scale tier: 1k-10k-client interleaved rounds on the event-heap
# scheduler (fl.chunking._run_event_heap).  The legacy per-frame scan
# rebuilt the contender list for every frame -- O(N) per frame, so a
# 1,000-client round was a timeout; the event heap makes it a bench row.
#
# `--check` is the CI scale gate: every row must complete (all sessions
# ACKed) and the 1k / 10k rows must land under their wall-clock budgets.
# `--out` writes the fresh rows before the budget assertions, so a
# failing gate still produces the artifact.
from __future__ import annotations

import argparse
import json
import sys
import time
import uuid
from pathlib import Path

_REPO = Path(__file__).resolve().parent.parent
if str(_REPO) not in sys.path:
    sys.path.insert(0, str(_REPO))

# Wall-clock budgets (seconds) for the gated rows.  Local runs land ~20x
# under these; the headroom absorbs slow shared CI runners, not real
# scheduler regressions (an O(N)-per-frame scheduler blows through them
# by orders of magnitude at these cohort sizes).
BUDGET_1K_S = 90.0
BUDGET_10K_S = 300.0

# Row shapes: cohort size, model params, chunk elems.  The 1k row keeps
# enough frames per client (~34) that scheduling dominates; the 10k
# smoke shrinks the model so the row stays a smoke test, not a soak.
ROWS = [
    ("64c", 64, 512, 256),
    ("256c", 256, 512, 256),
    ("1k", 1000, 512, 256),
    ("10k_smoke", 10_000, 64, 64),
]
POLICY_ROW_CLIENTS = 256
POLICIES = ("seeded-random", "shortest-remaining-first", "deadline-aware")


def _run_round(n_clients: int, n_elems: int, chunk_elems: int,
               *, arbitration: str = "seeded-random",
               hetero: bool = False) -> dict:
    from repro.fl.chunking import (
        AssemblerReceiver,
        UplinkSession,
        chunk_stream,
        run_interleaved_uplinks,
    )
    from repro.transport.medium import SharedMedium

    mid = uuid.UUID(int=9)
    import numpy as np

    def mk_session(c: int):
        # hetero: every 8th client carries a 4x model — the straggler
        # minority that state-aware arbitration policies reorder around
        n = n_elems * 4 if hetero and c % 8 == 0 else n_elems
        params = (np.arange(n, dtype=np.float32) - n / 2) / 8.0
        return UplinkSession(
            c, list(chunk_stream(mid, 1, params, chunk_elems)),
            AssemblerReceiver(expected_elems=n))

    sessions = [mk_session(c) for c in range(n_clients)]
    medium = SharedMedium(seed=1, turnaround_s=0.05,
                          arbitration=arbitration)
    t0 = time.perf_counter()
    report = run_interleaved_uplinks(medium, sessions)
    wall_s = time.perf_counter() - t0
    energies = sorted(report.per_client_energy_j.values())
    duties = sorted(report.duty_cycle.values())
    done = [t for t in report.per_client_done_s.values() if t is not None]
    return {
        "clients": n_clients,
        "params": n_elems,
        "chunk_elems": chunk_elems,
        "policy": arbitration,
        "acked": sum(1 for s in sessions if s.acked),
        "frames": medium.frames_sent,
        "airtime_s": round(report.airtime_s, 6),
        "busy_s": round(report.busy_s, 6),
        "mean_done_s": round(sum(done) / len(done), 6) if done else None,
        "wall_s": round(wall_s, 3),
        "mean_energy_j": round(sum(energies) / len(energies), 6),
        "max_duty_cycle": round(duties[-1], 6),
    }


def run_json() -> tuple[list[str], dict]:
    """All scale rows + the per-policy comparison; returns (csv rows,
    the ``scale_rounds`` record for BENCH_codec.json)."""
    rows = ["label,clients,policy,frames,airtime_s,mean_done_s,wall_s,"
            "mean_energy_j,max_duty_cycle"]
    record: dict = {"rows": {}, "policies": {}}

    def fmt(label: str, r: dict) -> str:
        return (f"{label},{r['clients']},{r['policy']},{r['frames']},"
                f"{r['airtime_s']:.3f},{r['mean_done_s']:.3f},"
                f"{r['wall_s']:.3f},{r['mean_energy_j']:.6f},"
                f"{r['max_duty_cycle']:.4f}")

    for label, n_clients, n_elems, chunk_elems in ROWS:
        r = _run_round(n_clients, n_elems, chunk_elems)
        record["rows"][label] = r
        rows.append(fmt(label, r))
    # policy comparison on a heterogeneous cohort (straggler minority):
    # shortest-remaining-first minimizes mean completion, deadline-aware
    # minimizes the straggler's finish — the mean_done_s column shows it
    for policy in POLICIES:
        r = _run_round(POLICY_ROW_CLIENTS, 512, 256, arbitration=policy,
                       hetero=True)
        record["policies"][policy] = r
        rows.append(fmt(f"policy_{policy}", r))
    return rows, record


def check(out: str | None = None) -> int:
    rows, record = run_json()
    print("\n".join(rows))
    if out:
        Path(out).write_text(json.dumps({"scale_rounds": record}, indent=2)
                             + "\n")
        print(f"check: wrote fresh scale rows to {out}")
    failed = False
    for label, r in {**record["rows"], **record["policies"]}.items():
        if r["acked"] != r["clients"]:
            failed = True
            print(f"check: {label}: only {r['acked']}/{r['clients']} "
                  "sessions completed")
    for label, budget in (("1k", BUDGET_1K_S), ("10k_smoke", BUDGET_10K_S)):
        wall = record["rows"][label]["wall_s"]
        if wall > budget:
            failed = True
            print(f"check: {label} round took {wall:.1f}s "
                  f"(budget {budget:.0f}s)")
        else:
            print(f"check: {label} round {wall:.1f}s "
                  f"<= budget {budget:.0f}s")
    if failed:
        return 1
    print("check: OK (all scale rows completed within budget)")
    return 0


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--check", action="store_true",
                        help="gate: every row completes, 1k/10k rows under "
                             "their wall-clock budgets")
    parser.add_argument("--out", default=None,
                        help="write the fresh scale rows to this path "
                             "(before the budget assertions)")
    args = parser.parse_args()
    if args.check:
        return check(args.out)
    rows, _ = run_json()
    print("\n".join(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
